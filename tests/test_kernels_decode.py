"""Pallas decode-attention kernel vs oracle: shape/dtype/length sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

SHAPES = [
    # (b, t, h, kh, hd)
    (2, 128, 4, 2, 64),
    (1, 512, 8, 8, 128),
    (3, 96, 8, 2, 32),     # padding path
    (2, 256, 8, 1, 64),    # MQA
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_matches_ref(shape, dtype):
    b, t, h, kh, hd = shape
    ks = jax.random.split(jax.random.PRNGKey(hash(shape) % 2**31), 4)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kh, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kh, hd), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, t + 1)
    out = decode_attention(q, k, v, lengths, blk_k=64, interpret=True)
    ref = decode_attention_ref(q, k, v, lengths)
    atol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=atol, rtol=atol)


def test_decode_length_one_returns_v0():
    b, t, h, hd = 2, 128, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    k = jax.random.normal(ks[1], (b, t, h, hd))
    v = jax.random.normal(ks[2], (b, t, h, hd))
    out = decode_attention(q, k, v, jnp.ones((b,), jnp.int32), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, 0]), atol=1e-5)


def test_decode_block_size_invariance():
    b, t, h, kh, hd = 2, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(6), 4)
    q = jax.random.normal(ks[0], (b, h, hd))
    k = jax.random.normal(ks[1], (b, t, kh, hd))
    v = jax.random.normal(ks[2], (b, t, kh, hd))
    lengths = jnp.asarray([100, 256], jnp.int32)
    o1 = decode_attention(q, k, v, lengths, blk_k=32, interpret=True)
    o2 = decode_attention(q, k, v, lengths, blk_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_decode_agrees_with_flash_last_row():
    """Decode of the last position == flash attention's last row."""
    from repro.kernels.flash_attention.ops import flash_attention
    b, s, h, kh, hd = 1, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kh, hd))
    v = jax.random.normal(ks[2], (b, s, kh, hd))
    full = flash_attention(q, k, v, causal=True, interpret=True)
    dec = decode_attention(q[:, -1], k, v, jnp.full((b,), s, jnp.int32),
                           interpret=True)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               atol=1e-5)
