"""Backend parity: the analytic simulator and the real-JAX engine cluster
are two backends of one ControlPlane — under the parity protocol (explicit
template traces, zero jitter, frozen load views, serialized engine runs)
their routing decisions, per-worker overlap vectors and saturation-regime
transition sequences must agree decision-for-decision.

Also covers the engine-path satellite fixes: the single-route overlap
recording (no self-credit), per-token ITL metrics, the returned-slot
contract, per-non-resident-block transfer charging, and real prefix reuse
(warm prefill skips jitted compute, logits stay exact).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.controller import violation_rates
from repro.models import build_model
from repro.serving.disagg import DisaggregatedCluster, ServeRequest
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.scenarios import build_backend, parity_scenarios
from repro.serving.workload import template_tokens

# real-model runs (jit compiles per prompt shape): tier-2 only
pytestmark = pytest.mark.slow

PARITY_SCENARIOS = parity_scenarios()


@pytest.fixture(scope="module")
def reduced_model():
    cfg = get_reduced("phi4-mini-3.8b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0), jnp.bfloat16)
    return cfg, model, params


def _toks(cfg, template, n=48):
    return [t % cfg.vocab_size for t in template_tokens(template, n)]


def _engine(reduced_model, **kw):
    cfg, model, params = reduced_model
    kw.setdefault("num_decode", 2)
    kw.setdefault("slots_per_worker", 2)
    kw.setdefault("max_len", 96)
    kw.setdefault("adaptive", False)
    return DisaggregatedCluster(model, params, **kw)


# ------------------------------------------------------------- parity -------


@pytest.mark.parametrize("engine_mode", [
    pytest.param(dict(batch_prefill=True, decode_impl="pallas"), id="fast"),
    pytest.param(dict(batch_prefill=False, decode_impl="sdpa"),
                 id="reference"),
    pytest.param(dict(batch_prefill=True, decode_impl="paged_sdpa"),
                 id="paged"),
])
@pytest.mark.parametrize("name", PARITY_SCENARIOS)
def test_backends_agree_on_decisions_and_regimes(name, engine_mode,
                                                 reduced_model):
    """τ=0 routing decisions, overlap vectors and the saturation-regime
    transition sequence are identical across backends — with the engine
    fast path (batched prefill + Pallas ragged decode) enabled as well as
    with the sequential `_sdpa` reference: the fast path must not perturb
    a single control-plane decision."""
    _, model, params = reduced_model
    sim = build_backend(name, backend="analytic", seed=0)
    res_a = sim.run()
    reqs_a = sorted(res_a.completed, key=lambda r: r.rid)
    decisions_a = [(r.rid, r.decode_worker, round(r.overlap, 12))
                   for r in reqs_a]
    vectors_a = [tuple(round(x, 12) for x in r.overlaps_all)
                 for r in reqs_a]

    eng = build_backend(name, backend="engine", seed=0,
                        model=model, params=params, **engine_mode)
    res_e = eng.run()
    decisions_e = [(i, w, round(ov, 12)) for i, w, ov in res_e.decisions]
    reqs_e = sorted(res_e.requests, key=lambda r: int(r.request_id[1:]))
    vectors_e = [tuple(round(x, 12) for x in r.overlaps) for r in reqs_e]

    assert decisions_a == decisions_e
    assert vectors_a == vectors_e
    # regime parity compares (from, to) sequences with timestamps
    # stripped: the two backends' clocks are incommensurable (sim-time vs
    # wall-time), the transition *order* is the shared observable
    assert [(a, b) for _, a, b in sim.detector.transitions] == \
        [(a, b) for _, a, b in res_e.regime_transitions]
    assert int(sim.detector.regime) == res_e.final_regime


def test_engine_backend_runs_sampled_scenarios(reduced_model):
    """Non-trace scenarios materialize a popularity-sampled stream on the
    engine backend (every registry scenario can instantiate either one)."""
    _, model, params = reduced_model
    eng = build_backend("70b-1p2d-ramp", backend="engine", seed=0,
                        num_requests=5, model=model, params=params,
                        output_tokens=2)
    res = eng.run()
    assert len(res.requests) == 5
    assert len(res.decisions) == 5
    assert all(len(r.output) >= 3 for r in res.requests)


# ------------------------------------------------- satellite regressions ----


def test_recorded_overlap_vector_is_pre_insert(reduced_model):
    """The recorded PoA counterfactual must come from the single routing
    call, BEFORE on_schedule inserts the request's own blocks — the old
    second ``best_worker`` call self-credited them (overlap 1.0 on the
    chosen worker of a cold first request)."""
    cfg, _, _ = reduced_model
    cluster = _engine(reduced_model, slots_per_worker=4)
    cluster.submit(ServeRequest("a0", _toks(cfg, 0), max_new_tokens=2))
    done = cluster.run_until_done()
    assert done[0].overlaps == (0.0, 0.0)      # cold pool: no self-credit
    # second request of the same template: the warm worker is credited
    cluster.submit(ServeRequest("a1", _toks(cfg, 0), max_new_tokens=2))
    done = cluster.run_until_done()
    warm = done[-1]
    assert warm.overlaps[done[0].worker] == 1.0


def test_decision_log_one_entry_per_placement(reduced_model):
    """Backpressure retries re-route a pending request every tick; the
    decision log must record one entry per *placement*, not one per
    abandoned routing attempt."""
    cfg, _, _ = reduced_model
    cluster = _engine(reduced_model, num_decode=1, slots_per_worker=1)
    for i in range(3):
        cluster.submit(ServeRequest(f"p{i}", _toks(cfg, i),
                                    max_new_tokens=2))
    cluster.run_until_done()
    rids = [d.rid for d in cluster.control.decision_log]
    assert sorted(rids) == ["p0", "p1", "p2"]


def test_per_token_itl_recorded(reduced_model):
    """Every decode step contributes an ITL sample, so violation_rates'
    ITL side is non-degenerate on the engine path."""
    cfg, _, _ = reduced_model
    cluster = _engine(reduced_model)
    for i in range(3):
        cluster.submit(ServeRequest(f"i{i}", _toks(cfg, i % 2),
                                    max_new_tokens=4))
    cluster.run_until_done()
    now = cluster._now()
    h = cluster.metrics.histogram("itl")
    # max_new=4 → first token from prefill + 4 decode steps per request
    assert h.count(now) == 3 * 4
    v_ttft, v_itl = violation_rates(cluster.metrics, 10.0, 10.0, now)
    assert v_itl == 0.0            # samples exist and sit far below the SLO
    _, v_itl_tight = violation_rates(cluster.metrics, 10.0, 0.0, now)
    assert v_itl_tight == 1.0      # ...and are real positive latencies


def test_decode_slot_readmittable_same_tick(reduced_model):
    """Returned-slot contract: done=True means the slot was released inside
    step() and can admit a new request in the same tick."""
    cfg, model, params = reduced_model
    pre = PrefillEngine(model, params, max_len=96)
    dec = DecodeEngine(model, params, num_slots=1, max_len=96)
    toks = _toks(cfg, 0)
    logits, caches = pre.prefill(toks)
    dec.admit(0, "r0", caches, int(np.argmax(logits)), len(toks), max_new=1,
              hashes=())
    assert dec.free_slot() is None
    out = dec.step()
    assert out and out[0][0] == "r0" and out[0][2] is True
    # same tick: the slot is already free and re-admittable
    assert dec.free_slot() == 0
    dec.admit(0, "r1", caches, int(np.argmax(logits)), len(toks), max_new=1,
              hashes=())
    assert dec.slots[0].request_id == "r1"


def test_transfer_charged_per_nonresident_block(reduced_model):
    """The prefill→decode hop moves only blocks the decode worker doesn't
    already hold: repeats on the warm worker ride free, a cold worker pays
    the full block count."""
    cfg, model, params = reduced_model
    pre = PrefillEngine(model, params, max_len=96)
    a = DecodeEngine(model, params, num_slots=2, max_len=96)
    b = DecodeEngine(model, params, num_slots=2, max_len=96, worker_id=1)
    toks = _toks(cfg, 0)
    from repro.core.radix import block_hashes
    hs = tuple(block_hashes(toks))
    logits, caches = pre.prefill(toks, hashes=hs)
    first = int(np.argmax(logits))
    assert a.admit(0, "r0", caches, first, len(toks), 1, hashes=hs) == len(hs)
    assert a.admit(1, "r1", caches, first, len(toks), 1, hashes=hs) == 0
    assert b.admit(0, "r2", caches, first, len(toks), 1, hashes=hs) == len(hs)
    assert a.transferred_blocks == len(hs)
    assert b.transferred_blocks == len(hs)


def test_warm_prefill_skips_compute_and_stays_exact(reduced_model):
    """Real prefix reuse: a warm prompt pass resumes from the matched block
    boundary (computed tokens drop) and reproduces the cold logits."""
    cfg, model, params = reduced_model
    assert model.supports_prefill_resume
    eng = PrefillEngine(model, params, max_len=96)
    toks = _toks(cfg, 0)
    cold_logits, _ = eng.prefill(toks)
    cold_tokens = eng.stats.computed_tokens
    assert eng.stats.reused_blocks == 0
    warm_logits, _ = eng.prefill(toks)
    warm_tokens = eng.stats.computed_tokens - cold_tokens
    # full-prefix hit: resume keeps exactly one suffix token (the pass must
    # emit THIS prompt's last-position logits), crediting 47//16 = 2 blocks
    assert eng.stats.reused_blocks == 2
    assert warm_tokens == 1
    assert np.allclose(cold_logits, warm_logits, rtol=2e-3, atol=2e-3)
    assert int(np.argmax(cold_logits)) == int(np.argmax(warm_logits))
    # a longer prompt sharing the prefix resumes too, and matches a
    # cache-disabled engine's from-scratch pass
    longer = _toks(cfg, 0, n=64)
    warm_long, _ = eng.prefill(longer)
    ref = PrefillEngine(model, params, max_len=96, cache_entries=0)
    cold_long, _ = ref.prefill(longer)
    assert ref.stats.reused_blocks == 0
    assert np.allclose(warm_long, cold_long, rtol=2e-3, atol=2e-3)
    assert int(np.argmax(warm_long)) == int(np.argmax(cold_long))


def test_prefix_cache_never_credits_other_templates(reduced_model):
    """Chained hashes: another template's blocks (even value-colliding ones
    after the vocab mod) must not be resumed from."""
    cfg, model, params = reduced_model
    eng = PrefillEngine(model, params, max_len=96)
    eng.prefill(_toks(cfg, 0))
    before = eng.stats.reused_blocks
    eng.prefill(_toks(cfg, 3))     # template 3 wraps into template 0's ids
    assert eng.stats.reused_blocks == before


def test_engine_template_reduction_is_injective(reduced_model):
    """Regression: plain ``template_tokens % vocab`` aliases templates 16
    apart on the 512-token reduced vocab (16·100_000 ≡ 0 mod 512) — the
    runner's in-vocab prompts must stay distinct across every template a
    wide-mix scenario can draw."""
    _, model, params = reduced_model
    eng = build_backend("parity-2d-warm", backend="engine", seed=0,
                        model=model, params=params, warmup=False)
    seen = {}
    for t in range(140):          # covers the scale-128 template universe
        toks = eng._spec(t, 48, 1).tokens
        assert toks not in seen, f"templates {seen[toks]} and {t} alias"
        seen[toks] = t


def test_disagg_greedy_continuation_warm_path(reduced_model):
    """End-to-end: a warm (resumed) request produces the same greedy
    continuation as the cold request of the same prompt."""
    cfg, _, _ = reduced_model
    cluster = _engine(reduced_model, slots_per_worker=4)
    toks = _toks(cfg, 0)
    cluster.submit(ServeRequest("c", toks, max_new_tokens=5))
    cold = cluster.run_until_done()[-1].output
    assert cluster.prefill.stats.reused_blocks == 0
    cluster.submit(ServeRequest("w", toks, max_new_tokens=5))
    warm = cluster.run_until_done()[-1].output
    assert cluster.prefill.stats.reused_blocks > 0
    assert warm == cold
