"""Table 4 / Experiment 1: equilibrium characterization — Nemotron-4-340B
1P/2D across 14 concurrency levels (TTFT/ITL P99, PoA, rps, regime)."""
from __future__ import annotations

import time

from benchmarks.common import emit, run_sim, save_json
from repro.core.saturation import DetectorConfig, SaturationDetector

LEVELS = [1, 2, 4, 8, 16, 32, 48, 64, 96, 128, 192, 256, 384, 512]


def run(hold_s: float = 120.0):
    t0 = time.perf_counter()
    rows = []
    print(f"\n# Table 4 — Experiment 1: 340B 1P/2D equilibrium sweep")
    print(f"{'C':>5} {'TTFT P99':>10} {'ITL P99':>9} {'PoA':>8} "
          f"{'rps':>6} {'regime':>10}")
    for c in LEVELS:
        res = run_sim("nemotron-4-340b", "1P/2D", c, hold_s)
        s = res.overall()
        det = SaturationDetector(DetectorConfig.for_model("nemotron-4-340b"))
        regime = max(p["regime"] for p in res.poll_log[3:] or res.poll_log)
        name = ["Below", "Transition", "Saturated"][regime]
        tag = "†" if c <= 4 else ""  # estimator artifact rows (paper Table 4)
        print(f"{c:>5} {s.ttft_p99:>9.3f}s {s.itl_p99*1000:>7.2f}ms "
              f"{s.poa:>8.2f}{tag} {s.rps:>6.1f} {name:>10}")
        rows.append(dict(C=c, ttft_p99=s.ttft_p99, itl_p99=s.itl_p99,
                         poa=s.poa, rps=s.rps, regime=name))
    save_json("table4_equilibrium", rows)
    dt = (time.perf_counter() - t0) * 1e6
    plateau = [r["poa"] for r in rows if 32 <= r["C"] <= 96]
    # first grid point past the knee: a ≥3x TTFT jump that also crosses the
    # 1 s absolute level (same criterion across models; cf. Table 5's
    # finite-difference version)
    knee = next((r["C"] for i, r in enumerate(rows[1:], 1)
                 if r["ttft_p99"] > 3 * rows[i - 1]["ttft_p99"]
                 and r["ttft_p99"] > 1.0 and r["C"] >= 64), None)
    emit("table4_equilibrium", dt / len(LEVELS),
         f"plateau_poa={sum(plateau)/len(plateau):.1f};"
         f"first_C_with_ttft_jump={knee} "
         f"(Table 5's finite-difference metric is the paper-comparable "
         f"knee locator)")
    return rows


if __name__ == "__main__":
    run()
