"""Latency model of Section 5: linear regime (Eq. 8) plus the singular
saturation term (Eq. 9), and the regime-transition signal (Prop. 4(iii)).

    f_j(n) = a_j·n + b_j + d_j / (n_sat − n)^β        (n < n_sat)

The pole at ``n_sat`` is what drives the PoA divergence; beyond the pole we
model explicit queueing (handled by the simulator's queues, not by this
function), so ``f_j`` is clamped at ``n_sat - margin``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class LatencyParams:
    a: float = 0.005          # linear slope (s per in-flight request)
    b: float = 0.020          # base latency (s)
    d: float = 0.010          # singular-term scale
    beta: float = 2.0         # pole severity
    n_sat: float = 64.0       # saturation point (in-flight requests)


# The paper's frozen PoA cost-matrix parameters (Section 6.4) — deliberately
# NOT fitted to observed latencies; they define the relative-efficiency index.
POA_FROZEN = LatencyParams(a=0.005, b=0.020, d=0.010, beta=2.0, n_sat=64.0)
POA_CACHE_WEIGHT = 0.015      # w_c in the Hungarian cost matrix


def latency(n, p: LatencyParams = POA_FROZEN, margin: float = 1.0):
    """Eq. 8/9 latency for load n (array-friendly)."""
    n = np.asarray(n, dtype=np.float64)
    n_eff = np.minimum(n, p.n_sat - margin)
    sing = p.d / np.power(p.n_sat - n_eff, p.beta)
    return p.a * n + p.b + sing


def latency_second_derivative(n, p: LatencyParams = POA_FROZEN):
    """f''(n) = β(β+1)·d/(n_sat−n)^{β+2} — diverges at the pole; the
    theoretical saturation signal of Prop. 4(iii)."""
    n = np.asarray(n, dtype=np.float64)
    gap = np.maximum(p.n_sat - n, 1e-9)
    return p.beta * (p.beta + 1) * p.d / np.power(gap, p.beta + 2)


def routing_cost(n_j, overlap, p: LatencyParams = POA_FROZEN,
                 w_c: float = POA_CACHE_WEIGHT):
    """The frozen-parameter per-(request, worker) cost used by the PoA
    estimator's Hungarian denominator:  c_ij = a·n_j + b + d/(C_j−n_j)^β −
    w_c·o_ij  (Section 6.4)."""
    return latency(n_j, p) - w_c * np.asarray(overlap, dtype=np.float64)
