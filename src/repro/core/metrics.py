"""Prometheus-style in-process metrics: gauges, counters, histograms with
percentile queries, and sliding windows — the observability substrate the
paper's controller polls (game_poa, game_saturation_state,
game_router_temperature, game_routing_cost)."""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple


class Gauge:
    def __init__(self, name: str, desc: str = ""):
        self.name, self.desc = name, desc
        self.value = 0.0

    def set(self, v: float):
        self.value = float(v)


class Counter:
    def __init__(self, name: str, desc: str = ""):
        self.name, self.desc = name, desc
        self.value = 0.0

    def inc(self, v: float = 1.0):
        self.value += v


class Histogram:
    """Windowed histogram over (timestamp, value) observations."""

    def __init__(self, name: str, desc: str = "", window_s: float = 60.0):
        self.name, self.desc = name, desc
        self.window_s = window_s
        self._obs: Deque[Tuple[float, float]] = deque()

    def observe(self, value: float, now: float):
        self._obs.append((now, value))
        self._trim(now)

    def _trim(self, now: float):
        while self._obs and self._obs[0][0] < now - self.window_s:
            self._obs.popleft()

    def values(self, now: Optional[float] = None) -> List[float]:
        if now is not None:
            self._trim(now)
        return [v for _, v in self._obs]

    def percentile(self, q: float, now: Optional[float] = None) -> float:
        vs = sorted(self.values(now))
        if not vs:
            return 0.0
        idx = min(len(vs) - 1, max(0, math.ceil(q / 100.0 * len(vs)) - 1))
        return vs[idx]

    def p99(self, now: Optional[float] = None) -> float:
        return self.percentile(99.0, now)

    def mean(self, now: Optional[float] = None) -> float:
        vs = self.values(now)
        return sum(vs) / len(vs) if vs else 0.0

    def count(self, now: Optional[float] = None) -> int:
        return len(self.values(now))

    def frac_above(self, threshold: float, now: Optional[float] = None) -> float:
        """Fraction of windowed observations above ``threshold`` — the SLO
        violation rate the Game 1 Planner polls (0.0 on an empty window)."""
        vs = self.values(now)
        if not vs:
            return 0.0
        return sum(1 for v in vs if v > threshold) / len(vs)


class MetricsRegistry:
    """Named registry; ``export_text()`` emits Prometheus exposition format."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def gauge(self, name: str, desc: str = "") -> Gauge:
        return self._get(name, lambda: Gauge(name, desc))

    def counter(self, name: str, desc: str = "") -> Counter:
        return self._get(name, lambda: Counter(name, desc))

    def histogram(self, name: str, desc: str = "", window_s: float = 60.0) -> Histogram:
        return self._get(name, lambda: Histogram(name, desc, window_s))

    def _get(self, name, factory):
        if name not in self._metrics:
            self._metrics[name] = factory()
        return self._metrics[name]

    def export_text(self, now: Optional[float] = None) -> str:
        lines = []
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, (Gauge, Counter)):
                lines.append(f"# HELP {name} {m.desc}")
                lines.append(f"{name} {m.value}")
            elif isinstance(m, Histogram):
                lines.append(f"# HELP {name} {m.desc}")
                lines.append(f"{name}_count {m.count(now)}")
                lines.append(f"{name}_p50 {m.percentile(50, now)}")
                lines.append(f"{name}_p99 {m.p99(now)}")
        return "\n".join(lines) + "\n"
