"""Pure-jnp oracle for causal GQA flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,S,H,hd); k,v: (B,T,K,hd) with H = K·G. fp32 softmax.
    Returns (B,S,H,hd) in q.dtype."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qf = q.astype(jnp.float32).reshape(b, s, kh, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgh,btkh->bkgst", qf, kf) / np.sqrt(hd)
    if causal:
        qi = jnp.arange(s)[:, None]
        ki = jnp.arange(t)[None, :]
        mask = (ki <= qi + (t - s))  # allow offset caches (t >= s)
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, vf)
    return out.reshape(b, s, h, hd).astype(q.dtype)
